"""Lock-discipline pass (rules LCK001-LCK005).

The serving stack's declared lock hierarchy, outermost first::

    server (10)  ->  scheduler (20)  ->  dispatch (25)  ->  store (30)
       ->  plans_sync (35)  ->  leaf {stats, trace, metrics, watchdog,
                                      rcache, tenancy} (40)

A thread may acquire a lock only while holding locks of strictly lower
level (re-acquiring a held RLock domain is fine). Leaf locks may never
be held across *any* unresolved outbound call; the store lock and the
leaves may not be held across blocking operations (device syncs,
``Condition.wait`` on a foreign lock, joins, sleeps) or
listener/callback invocations — the scheduler, by contrast, *does*
hold its lock across the device step by design.

Lock construction sites bind an attribute to a domain with a
``# lock: <domain>`` comment; every `threading.Lock/RLock/Condition`
constructed in a scanned file must carry one (LCK005).

Rules:

* **LCK001** lock-order inversion: acquiring a domain whose level is
  <= a held domain's level (same-domain re-entry on an RLock exempt).
* **LCK002** leaf lock held across an unresolved outbound call.
* **LCK003** blocking operation under a domain that forbids blocking
  (``Condition.wait`` on the held lock's own condition is exempt —
  it releases the lock).
* **LCK004** listener/callback invocation while holding the store lock
  or a leaf lock.
* **LCK005** unregistered lock: construction without a ``# lock:``
  annotation, or an annotation naming an undeclared domain.

Cross-module effects are modelled by declaration: ``ATTR_DOMAINS`` maps
well-known object attributes (``self.store``, ``self.stats``, the
scheduler's injected callbacks) to the set of domains a call through
them may acquire, so ordering is checked across module boundaries
without whole-program resolution.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding, SourceFile, attr_chain

__all__ = ["LockDomain", "HIERARCHY", "ATTR_DOMAINS", "LockPass"]


@dataclasses.dataclass(frozen=True)
class LockDomain:
    name: str
    level: int
    reentrant: bool = False      # RLock: same-domain re-entry is legal
    leaf: bool = False           # no outbound calls while held
    blocking_ok: bool = True     # may block (device sync, wait, join)


HIERARCHY: Dict[str, LockDomain] = {d.name: d for d in [
    LockDomain("server", 10, reentrant=True),
    LockDomain("scheduler", 20, reentrant=True),
    LockDomain("dispatch", 25),
    LockDomain("store", 30, reentrant=True, blocking_ok=False),
    LockDomain("plans_sync", 35, blocking_ok=False),
    LockDomain("tenancy", 40, leaf=True, blocking_ok=False),
    LockDomain("stats", 40, leaf=True, blocking_ok=False),
    LockDomain("trace", 40, leaf=True, blocking_ok=False),
    LockDomain("metrics", 40, leaf=True, blocking_ok=False),
    LockDomain("watchdog", 40, leaf=True, blocking_ok=False),
    LockDomain("rcache", 40, leaf=True, blocking_ok=False),
]}

# Object attributes through which cross-module lock acquisitions happen.
# ``self.store.acquire(...)`` may take the store lock; the continuous
# scheduler's injected callbacks acquire what their server-side
# implementations acquire (documented contracts, checked on the server
# side by this same pass).
ATTR_DOMAINS: Dict[str, Set[str]] = {
    "store": {"store"}, "_store": {"store"},
    "stats": {"stats"}, "_stats": {"stats"},
    "trace": {"trace"}, "_trace": {"trace"}, "bus": {"trace"},
    "metrics": {"metrics"}, "_metrics": {"metrics"},
    "tenants": {"tenancy"},
    "plans": {"plans_sync", "store", "stats"},
    "_continuous": {"scheduler", "dispatch", "store", "plans_sync",
                    "stats", "trace", "metrics", "rcache", "tenancy"},
    # continuous-scheduler injection seams (ContinuousScheduler ctor)
    "_get_stepper": {"dispatch", "store", "plans_sync", "stats",
                     "trace", "metrics"},
    "_on_result": {"rcache"},
    "_acquire": {"store"},
    "_park_charge": {"store"}, "_park_release": {"store"},
    "_charge": {"store"}, "_release": {"store"},
    "_weight": {"tenancy"},
    # store listener lists (server purge + plan-cache invalidation)
    "_evict_listeners": {"plans_sync", "stats", "store", "rcache"},
    "_spill_listeners": {"plans_sync"},
    "_refault_listeners": {"plans_sync"},
}

# Completing a Future runs its done-callbacks on the calling thread;
# the service attaches lease releases there, which take the store lock.
METHOD_DOMAINS: Dict[str, Set[str]] = {
    "set_result": {"store"},
    "set_exception": {"store"},
}

CALLBACK_ATTRS = {
    "_evict_listeners", "_spill_listeners", "_refault_listeners",
    "_discard_listeners", "_on_result", "_get_stepper", "_acquire",
    "_park_charge", "_park_release", "_charge", "_release", "_weight",
    "_collectors",
}

BLOCKING_METHODS = {"wait", "join", "result", "block_until_ready",
                    "device_put", "sleep"}

# Pure-python helpers / containers: calling these never leaves the
# module or blocks.
SAFE_CALLS = {
    "len", "int", "float", "str", "bool", "list", "dict", "set",
    "tuple", "frozenset", "sorted", "reversed", "min", "max", "sum",
    "abs", "round", "any", "all", "enumerate", "zip", "range", "map",
    "filter", "isinstance", "issubclass", "getattr", "setattr",
    "hasattr", "repr", "format", "id", "hash", "iter", "next", "type",
    "divmod", "print", "vars", "super", "ValueError", "KeyError",
    "RuntimeError", "TypeError", "AssertionError", "StopIteration",
    "Exception", "object",
}
SAFE_MODULES = {"math", "np", "numpy", "collections", "dataclasses",
                "itertools", "bisect", "json", "re", "heapq",
                "statistics", "os"}
SAFE_MODULE_FUNCS = {("time", "perf_counter"), ("time", "monotonic"),
                     ("time", "time")}
CONTAINER_METHODS = {
    "append", "appendleft", "extend", "extendleft", "pop", "popleft",
    "popitem", "push", "get", "items", "keys", "values", "setdefault",
    "update", "move_to_end", "add", "remove", "discard", "clear",
    "insert", "index", "count", "copy", "sort", "reverse", "join",
    "split", "rsplit", "strip", "lstrip", "rstrip", "startswith",
    "endswith", "format", "replace", "lower", "upper", "encode",
    "decode", "notify", "notify_all", "total_seconds", "isoformat",
    "astype", "tolist", "item", "sum", "mean", "reshape", "most_common",
    "is_integer", "bit_length", "title", "capitalize", "zfill",
}

_LOCK_CTORS = {"Lock", "RLock", "Condition"}


@dataclasses.dataclass(frozen=True)
class LockBinding:
    domain: str
    is_condition: bool
    line: int


class _Effect:
    """One thing a function (transitively) does that matters under a
    lock. ``kind``: acquire | domains | outcall | callback | blocking.
    ``site`` is the (SourceFile, line, scope) where it textually
    happens — findings anchor there so one ``allow`` annotation covers
    every caller path."""

    __slots__ = ("kind", "domains", "detail", "sf", "line", "scope",
                 "cond_domain")

    def __init__(self, kind, domains, detail, sf, line, scope,
                 cond_domain=None):
        self.kind = kind
        self.domains = domains
        self.detail = detail
        self.sf = sf
        self.line = line
        self.scope = scope
        self.cond_domain = cond_domain


class _FnIndex:
    """Functions of one module, resolvable by (class, name) and name."""

    def __init__(self, sf: SourceFile):
        self.sf = sf
        self.by_qual: Dict[Tuple[Optional[str], str], ast.AST] = {}
        self.by_name: Dict[str, List[Tuple[Optional[str], ast.AST]]] = {}
        self.classes: Set[str] = set()
        for node in sf.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add(None, node)
            elif isinstance(node, ast.ClassDef):
                self.classes.add(node.name)
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        self._add(node.name, sub)

    def _add(self, cls: Optional[str], fn: ast.AST):
        self.by_qual[(cls, fn.name)] = fn
        self.by_name.setdefault(fn.name, []).append((cls, fn))


class LockPass:
    """Runs the lock-discipline rules over a set of source files."""

    name = "locks"

    def __init__(self, hierarchy: Optional[Dict[str, LockDomain]] = None,
                 attr_domains: Optional[Dict[str, Set[str]]] = None):
        self.hierarchy = dict(hierarchy or HIERARCHY)
        self.attr_domains = dict(attr_domains or ATTR_DOMAINS)

    # -------------------- binding collection ------------------------
    def _collect_bindings(self, files: Sequence[SourceFile],
                          findings: List[Finding]):
        """(module, class|None, attr) -> LockBinding, plus per-module
        attr fallbacks when unambiguous."""
        bindings: Dict[Tuple[str, Optional[str], str], LockBinding] = {}
        for sf in files:
            stack: List[ast.AST] = []

            def visit(node, cls):
                for child in ast.iter_child_nodes(node):
                    ncls = cls
                    if isinstance(child, ast.ClassDef):
                        ncls = child.name
                    self._bind_in_node(sf, child, cls, bindings, findings)
                    visit(child, ncls)

            visit(sf.tree, None)
        return bindings

    def _bind_in_node(self, sf, node, cls, bindings, findings):
        if not isinstance(node, (ast.Assign, ast.AnnAssign, ast.keyword)):
            # lock ctor as a call keyword: Foo(cond=threading.Condition())
            if isinstance(node, ast.Call):
                for kw in node.keywords:
                    if self._is_lock_ctor(kw.value):
                        self._register(sf, kw.value, cls, kw.arg,
                                       bindings, findings)
            return
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AnnAssign):
            value, targets = node.value, [node.target]
        else:
            return
        if value is None or not self._is_lock_ctor(value):
            return
        for t in targets:
            if isinstance(t, ast.Attribute):
                self._register(sf, value, cls, t.attr, bindings, findings)
            elif isinstance(t, ast.Name):
                self._register(sf, value, cls, t.id, bindings, findings)

    @staticmethod
    def _is_lock_ctor(value) -> bool:
        if not isinstance(value, ast.Call):
            return False
        chain = attr_chain(value.func)
        return bool(chain) and chain[-1] in _LOCK_CTORS and (
            len(chain) == 1 or chain[0] in ("threading", "th"))

    def _register(self, sf, value, cls, attr, bindings, findings):
        chain = attr_chain(value.func)
        is_cond = chain[-1] == "Condition"
        text = sf.line_text(value.lineno)
        import re as _re
        m = _re.search(r"#\s*lock:\s*([\w-]+)", text)
        if not m:
            if not sf.allows(value.lineno, "LCK005"):
                findings.append(sf.make(
                    "LCK005", value, cls or "<module>",
                    f"lock construction for {attr!r} has no "
                    f"'# lock: <domain>' annotation"))
            return
        domain = m.group(1)
        if domain not in self.hierarchy:
            findings.append(sf.make(
                "LCK005", value, cls or "<module>",
                f"annotation '# lock: {domain}' names an undeclared "
                f"domain (declared: {sorted(self.hierarchy)})"))
            return
        bindings[(sf.rel, cls, attr)] = LockBinding(
            domain, is_cond, value.lineno)

    def _lookup(self, bindings, sf, cls, attr) -> Optional[LockBinding]:
        b = bindings.get((sf.rel, cls, attr))
        if b:
            return b
        # module-wide fallback when the attr name is unambiguous there
        cands = [v for (rel, _c, a), v in bindings.items()
                 if rel == sf.rel and a == attr]
        if len({c.domain for c in cands}) == 1:
            return cands[0]
        # cross-module: unique attr name anywhere (entry.cond style)
        cands = [v for (_r, _c, a), v in bindings.items() if a == attr]
        if len({c.domain for c in cands}) == 1:
            return cands[0]
        return None

    # -------------------- effect summaries --------------------------
    def run(self, files: Sequence[SourceFile]) -> List[Finding]:
        findings: List[Finding] = []
        bindings = self._collect_bindings(files, findings)
        for sf in files:
            idx = _FnIndex(sf)
            memo: Dict[int, List[_Effect]] = {}
            visiting: Set[int] = set()
            for (cls, name), fn in idx.by_qual.items():
                scope = f"{cls}.{name}" if cls else name
                self._check_function(sf, idx, fn, cls, scope, bindings,
                                     memo, visiting, findings)
        # dedup (multiple caller paths reach the same effect site)
        seen, out = set(), []
        for f in findings:
            key = (f.rule, f.path, f.line, f.message)
            if key not in seen:
                seen.add(key)
                out.append(f)
        return out

    def _effects_of(self, sf, idx, fn, cls, scope, bindings, memo,
                    visiting) -> List[_Effect]:
        key = id(fn)
        if key in memo:
            return memo[key]
        if key in visiting:
            return []
        visiting.add(key)
        effects: List[_Effect] = []
        loop_vars = self._listener_loop_vars(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.With):
                for item in node.items:
                    b = self._with_lock(bindings, sf, cls, item)
                    if b:
                        effects.append(_Effect(
                            "acquire", {b.domain}, f"lock '{b.domain}'",
                            sf, node.lineno, scope))
            elif isinstance(node, ast.Call):
                effects.extend(self._classify_call(
                    sf, idx, node, cls, scope, bindings, memo, visiting,
                    loop_vars))
        visiting.discard(key)
        memo[key] = effects
        return effects

    @staticmethod
    def _listener_loop_vars(fn) -> Dict[str, str]:
        """Loop targets iterating ``self.<attr>`` / ``list(self.<attr>)``
        -> attr (callback lists)."""
        out: Dict[str, str] = {}
        for node in ast.walk(fn):
            if not isinstance(node, ast.For):
                continue
            it = node.iter
            if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                    and it.func.id in ("list", "tuple") and it.args):
                it = it.args[0]
            chain = attr_chain(it)
            if chain and isinstance(node.target, ast.Name):
                out[node.target.id] = chain[-1]
        return out

    def _with_lock(self, bindings, sf, cls, item) -> Optional[LockBinding]:
        chain = attr_chain(item.context_expr)
        if not chain:
            return None
        # foreign receiver (store._lock, svc.store._lock): the owner's
        # declared domain wins over any same-named attr in this class
        if len(chain) >= 3 or (len(chain) == 2
                               and chain[0] not in ("self", "cls")):
            owner = chain[-2]
            domains = self.attr_domains.get(owner)
            if domains and len(domains) == 1:
                return LockBinding(next(iter(domains)), False, 0)
        return self._lookup(bindings, sf, cls, chain[-1])

    def _classify_call(self, sf, idx, call, cls, scope, bindings, memo,
                       visiting, loop_vars) -> List[_Effect]:
        func = call.func
        line = call.lineno
        # --- bare-name calls -----------------------------------------
        if isinstance(func, ast.Name):
            name = func.id
            if name in SAFE_CALLS:
                return []
            if name in loop_vars:
                attr = loop_vars[name]
                domains = self.attr_domains.get(attr, set())
                return [_Effect("callback", domains,
                                f"listener from '{attr}'", sf, line,
                                scope)]
            target = self._resolve(idx, cls, None, name)
            if target is not None:
                sub = f"{cls}.{name}" if cls else name
                return self._effects_of(sf, idx, target[1], target[0],
                                        sub, bindings, memo, visiting)
            if name in idx.classes:
                # same-module constructor: its effects are __init__'s
                init = idx.by_qual.get((name, "__init__"))
                if init is None:
                    return []
                return self._effects_of(sf, idx, init,
                                        name, f"{name}.__init__",
                                        bindings, memo, visiting)
            return [_Effect("outcall", set(), f"call to '{name}'",
                            sf, line, scope)]
        # --- attribute calls -----------------------------------------
        # a method on a string/number literal (",".join, ...) is pure —
        # and must not collide with Thread.join in BLOCKING_METHODS
        if isinstance(func, ast.Attribute) and \
                isinstance(func.value, ast.Constant):
            return []
        chain = attr_chain(func)
        if chain is None:
            # chained / subscripted receiver: classify by method name
            if isinstance(func, ast.Attribute):
                if func.attr in BLOCKING_METHODS:
                    return [_Effect("blocking", set(),
                                    f"blocking '{func.attr}()'", sf,
                                    line, scope)]
                if func.attr in CONTAINER_METHODS:
                    return []
            return [_Effect("outcall", set(), "dynamic call", sf, line,
                            scope)]
        method = chain[-1]
        recv = chain[-2] if len(chain) >= 2 else None
        root = chain[0]
        # blocking first (Condition.wait on own lock handled by caller)
        if method in BLOCKING_METHODS:
            cond_domain = None
            if method == "wait" and recv is not None:
                b = self._lookup(bindings, sf, cls, recv)
                if b is not None:
                    cond_domain = b.domain
            return [_Effect("blocking", set(), f"blocking '{method}()'",
                            sf, line, scope, cond_domain=cond_domain)]
        if root in SAFE_MODULES or (root, method) in SAFE_MODULE_FUNCS \
                or (len(chain) >= 2 and chain[0] == "jnp"):
            return []
        if method in METHOD_DOMAINS:
            return [_Effect("domains", METHOD_DOMAINS[method],
                            f"'{method}()' (future completion runs "
                            f"lease-release callbacks)", sf, line, scope)]
        if root in ("self", "cls") and len(chain) == 2:
            # self.m(...): own method, or an injected callback attr
            if method in self.attr_domains and method in CALLBACK_ATTRS:
                return [_Effect("callback", self.attr_domains[method],
                                f"callback 'self.{method}'", sf, line,
                                scope)]
            target = self._resolve(idx, cls, cls, method)
            if target is not None:
                sub = f"{target[0]}.{method}" if target[0] else method
                return self._effects_of(sf, idx, target[1], target[0],
                                        sub, bindings, memo, visiting)
        if recv is not None and recv in self.attr_domains:
            domains = self.attr_domains[recv]
            kind = "callback" if recv in CALLBACK_ATTRS else "domains"
            return [_Effect(kind, domains,
                            f"call through '{recv}' (may acquire "
                            f"{sorted(domains)})", sf, line, scope)]
        if method in CONTAINER_METHODS:
            return []
        # method of a same-module class (head.spec() style): union over
        # every class defining that method name — except the enclosing
        # class itself (a non-self receiver is almost never another
        # instance of the class being analysed, and including it makes
        # Histogram.observe look like MetricsRegistry.observe)
        cands = [(c, f) for c, f in idx.by_name.get(method, ())
                 if c != cls]
        if cands and root != "self":
            effects: List[_Effect] = []
            for ccls, cfn in cands:
                sub = f"{ccls}.{method}" if ccls else method
                effects.extend(self._effects_of(
                    sf, idx, cfn, ccls, sub, bindings, memo, visiting))
            return effects
        return [_Effect("outcall", set(),
                        f"call to '{'.'.join(chain)}'", sf, line, scope)]

    @staticmethod
    def _resolve(idx, cls, want_cls, name):
        fn = idx.by_qual.get((want_cls, name))
        if fn is not None:
            return (want_cls, fn)
        fn = idx.by_qual.get((None, name))
        if fn is not None:
            return (None, fn)
        return None

    # -------------------- per-function check ------------------------
    def _check_function(self, sf, idx, fn, cls, scope, bindings, memo,
                        visiting, findings):
        loop_vars = self._listener_loop_vars(fn)

        def walk(node, held: List[str]):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                return  # nested defs run later, not here
            if isinstance(node, ast.With):
                new_held = list(held)
                for item in node.items:
                    b = self._with_lock(bindings, sf, cls, item)
                    if b:
                        self._check_acquire(sf, node.lineno, scope,
                                            b.domain, new_held, findings)
                        new_held = new_held + [b.domain]
                for sub in node.body:
                    walk(sub, new_held)
                return
            if isinstance(node, ast.Call) and held:
                effs = self._classify_call(
                    sf, idx, node, cls, scope, bindings, memo,
                    visiting, loop_vars)
                for e in effs:
                    self._check_effect(e, held, node.lineno, scope,
                                       sf, findings)
            for child in ast.iter_child_nodes(node):
                walk(child, held)

        for child in ast.iter_child_nodes(fn):
            walk(child, [])

    def _check_acquire(self, sf, line, scope, domain, held, findings):
        if not held:
            return
        d = self.hierarchy[domain]
        for h in held:
            hd = self.hierarchy[h]
            if h == domain:
                if not hd.reentrant and not sf.allows(line, "LCK001"):
                    findings.append(sf.make(
                        "LCK001", line, scope,
                        f"re-acquiring non-reentrant lock '{domain}' "
                        f"(self-deadlock)"))
                continue
            if d.level <= hd.level and not sf.allows(line, "LCK001"):
                findings.append(sf.make(
                    "LCK001", line, scope,
                    f"acquiring '{domain}' (level {d.level}) while "
                    f"holding '{h}' (level {hd.level}) inverts the "
                    f"declared order"))

    def _check_effect(self, e: _Effect, held: List[str], call_line,
                      caller_scope, caller_sf, findings):
        innermost = held[-1]
        leaf_held = [h for h in held if self.hierarchy[h].leaf]
        via = ("" if (e.sf is caller_sf and e.line == call_line)
               else f" (via {caller_scope}:{call_line})")

        def report(rule, msg):
            if e.sf.allows(e.line, rule):
                return
            findings.append(e.sf.make(rule, e.line, e.scope, msg + via))

        if e.kind == "acquire" or e.kind == "domains":
            for dom in e.domains:
                d = self.hierarchy.get(dom)
                if d is None:
                    continue
                for h in held:
                    hd = self.hierarchy[h]
                    if dom == h:
                        if not hd.reentrant:
                            report("LCK001",
                                   f"re-acquiring non-reentrant lock "
                                   f"'{dom}' ({e.detail})")
                        continue
                    if d.level <= hd.level:
                        report("LCK001",
                               f"may acquire '{dom}' (level {d.level}) "
                               f"while holding '{h}' (level {hd.level}): "
                               f"{e.detail}")
        elif e.kind == "callback":
            bad = leaf_held + [h for h in held if h == "store"]
            if bad:
                report("LCK004",
                       f"{e.detail} invoked while holding "
                       f"'{bad[-1]}' — listeners must fire with the "
                       f"lock released")
            # callbacks also carry their declared acquisitions
            if e.domains:
                self._check_effect(
                    _Effect("domains", e.domains, e.detail, e.sf, e.line,
                            e.scope), held, call_line, caller_scope,
                    caller_sf, findings)
        elif e.kind == "blocking":
            if e.cond_domain is not None and e.cond_domain in held:
                return  # Condition.wait on the held lock releases it
            blocked = [h for h in held
                       if not self.hierarchy[h].blocking_ok]
            if blocked:
                report("LCK003",
                       f"{e.detail} while holding '{blocked[-1]}', "
                       f"which forbids blocking")
        elif e.kind == "outcall":
            if leaf_held:
                report("LCK002",
                       f"leaf lock '{leaf_held[-1]}' held across "
                       f"{e.detail}")
        _ = innermost
