"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step, derived
from the PER-DEVICE partitioned module (compiled.cost_analysis() analyzes
the SPMD-partitioned per-device program):

  compute    = flops_per_device / PEAK_BF16
  memory     = bytes_per_device / HBM_BW
  collective = wire_bytes_per_device / ICI_LINK_BW

Collective bytes are NOT in cost_analysis: we parse the compiled HLO and
sum per-op wire costs with standard ring-algorithm factors, using each
op's replica_groups to get the participant count.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (one effective link per chip — conservative).
"""
from __future__ import annotations

import re
from typing import Dict, Optional

__all__ = ["HW", "parse_collectives", "roofline", "model_flops"]

PEAK_BF16 = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?\S+ = (?P<result>.+?) "
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, total_devices: int) -> Dict[str, float]:
    """Per-DEVICE wire bytes by collective op (ring-cost model):
      all-gather      result*(P-1)/P   (result = gathered)
      all-reduce      2*bytes*(P-1)/P
      reduce-scatter  operand ~ result*P -> result*(P-1)
      all-to-all      bytes*(P-1)/P
      collective-permute  bytes
    ``-start/-done`` async pairs are counted once (on -start or the sync
    form; ``-done`` lines don't match the value pattern)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0.0}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("result"))
        p = _group_size(line, total_devices)
        if p <= 1:
            continue
        if op == "all-gather":
            wire = nbytes * (p - 1) / p
        elif op == "all-reduce":
            wire = 2 * nbytes * (p - 1) / p
        elif op == "reduce-scatter":
            wire = nbytes * (p - 1)
        elif op == "all-to-all":
            wire = nbytes * (p - 1) / p
        else:  # collective-permute
            wire = nbytes
        out[op] += wire
        out["count"] += 1
    out["total_wire_bytes"] = sum(
        v for k, v in out.items() if k not in ("count", "total_wire_bytes"))
    return out


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward (MoE: N = active)."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_params_active * tokens


def analytic_hbm_bytes(*, n_params: int, n_params_active: int, tokens: int,
                       d_model: int, n_layers: int, vocab: int,
                       n_dev: int, dp: int, tp: int, kind: str,
                       microbatch: int = 1,
                       cache_bytes_per_dev: float = 0.0) -> float:
    """Fused-execution HBM-traffic estimate per device (lower bound; the
    HLO 'bytes accessed' from the CPU backend is an unfused UPPER bound —
    TPU fuses elementwise chains into the matmul pipelines).

    train: every microbatch streams the gathered weights 3x (fwd, remat
    fwd, bwd), the optimizer reads/writes grads f32 + m/v f32 + params,
    remat boundary activations are written+read once, logits 3 passes.
    prefill: one weight stream + KV-cache write.
    decode: one ACTIVE-weight stream (MoE touches topk/n experts at
    batch*1 tokens) + full cache read + cache write."""
    p_dev = 2.0 * n_params / max(tp, 1)  # TP-resident share per device
    tok_dev = tokens / max(dp, 1)
    act = tok_dev * d_model * 2.0 * n_layers
    if kind == "train":
        w = 3.0 * microbatch * p_dev             # gathered weight streams
        opt = 18.0 * n_params / n_dev            # g(4rw=8)+m,v(8)+p(2)
        logits = 3.0 * tokens * vocab * 4.0 / n_dev
        return w + opt + 2.0 * act + logits
    if kind == "prefill":
        return p_dev + 2.0 * act + cache_bytes_per_dev
    # decode
    return 2.0 * n_params_active / max(tp, 1) + 3.0 * cache_bytes_per_dev


def roofline(cost: dict, colls: Dict[str, float], *,
             n_devices: int, tokens: int, n_params_active: int,
             kind: str, analytic_bytes: Optional[float] = None
             ) -> Dict[str, float]:
    """Three-term roofline. The memory term has two sources: the HLO
    'bytes accessed' (UPPER bound: the CPU backend lowers elementwise
    chains unfused) and the analytic fused-execution estimate (LOWER
    bound; see analytic_hbm_bytes). Headline numbers use the analytic
    term when available; both are reported."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    wire_dev = colls.get("total_wire_bytes", 0.0)
    t_compute = flops_dev / PEAK_BF16
    t_memory_hlo = bytes_dev / HBM_BW
    t_memory = (analytic_bytes / HBM_BW if analytic_bytes is not None
                else t_memory_hlo)
    t_coll = wire_dev / ICI_LINK_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(n_params_active, tokens, kind)
    hlo_flops_global = flops_dev * n_devices
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_hlo_upper_s": t_memory_hlo,
        "t_collective_s": t_coll,
        "bound_by": dominant,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "analytic_bytes_per_device": analytic_bytes,
        "wire_bytes_per_device": wire_dev,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flop_ratio": (mf / hlo_flops_global
                              if hlo_flops_global else 0.0),
        # step time if perfectly overlapped = max term; roofline fraction =
        # useful-compute time over that bound.
        "roofline_step_s": max(t_compute, t_memory, t_coll),
        "mfu_bound": (mf / n_devices / PEAK_BF16)
                     / max(t_compute, t_memory, t_coll, 1e-30),
    }
