"""Production meshes.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (jax locks the device count on first backend
init, and the dry-run needs to set XLA_FLAGS first).

  single pod : (16, 16)    ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) ("pod", "data", "model") = 512 chips

The graph engine flattens every axis into one "graph" axis (the paper's
n_FPGA): 256- or 512-way vertex sharding.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_graph_mesh", "make_local_mesh",
           "compat_make_mesh"]


def compat_make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh across versions: newer jax wants explicit Auto
    axis_types; 0.4.x has no AxisType (Auto is the only behaviour)."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except AttributeError:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_graph_mesh(*, multi_pod: bool = False) -> Mesh:
    """All chips on one 'graph' axis for the GraVF-M engine."""
    n = 512 if multi_pod else 256
    return compat_make_mesh((n,), ("graph",))


def make_local_mesh(axes=("graph",)) -> Mesh:
    """Whatever devices exist locally (tests / reduced runs)."""
    n = len(jax.devices())
    return compat_make_mesh((n,), axes)
