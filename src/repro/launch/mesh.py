"""Production meshes.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (jax locks the device count on first backend
init, and the dry-run needs to set XLA_FLAGS first).

  single pod : (16, 16)    ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16) ("pod", "data", "model") = 512 chips

The graph engine flattens every axis into one "graph" axis (the paper's
n_FPGA): 256- or 512-way vertex sharding.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_graph_mesh", "make_local_mesh",
           "make_serving_mesh", "compat_make_mesh"]


def compat_make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh across versions: newer jax wants explicit Auto
    axis_types; 0.4.x has no AxisType (Auto is the only behaviour)."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except AttributeError:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_graph_mesh(*, multi_pod: bool = False) -> Mesh:
    """All chips on one 'graph' axis for the GraVF-M engine."""
    n = 512 if multi_pod else 256
    return compat_make_mesh((n,), ("graph",))


def make_local_mesh(axes=("graph",)) -> Mesh:
    """Whatever devices exist locally (tests / reduced runs)."""
    n = len(jax.devices())
    return compat_make_mesh((n,), axes)


def make_serving_mesh(num_shards: int) -> Mesh:
    """The service's explicit 1-D graph mesh: ``num_shards`` devices on
    the ``"graph"`` axis, one partition per device. Requires at least
    ``num_shards`` visible devices (real accelerators, or host-platform
    devices via ``--xla_force_host_platform_device_count=N`` set before
    jax's first backend init) — shard classes are a multi-device
    feature, and failing loudly here beats shard_map's late error."""
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    have = len(jax.devices())
    if have < num_shards:
        raise RuntimeError(
            f"serving mesh wants {num_shards} devices on the 'graph' "
            f"axis but only {have} are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_shards} "
            "before importing jax (or run on a platform with enough "
            "devices)")
    return compat_make_mesh((num_shards,), ("graph",))
