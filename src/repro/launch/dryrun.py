import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production meshes with ShapeDtypeStruct stand-ins
(no allocation), record memory/cost/collective analyses for EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --graph --exchange allgather

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the run exits nonzero.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from .. import sharding as SH
from ..configs.common import SHAPES, input_specs, shape_applicable
from ..models import encdec as ED
from ..models import layers as L
from ..models import lm as LM
from ..train.loop import make_train_step
from ..train.optimizer import AdamWConfig, adamw_init
from .mesh import make_graph_mesh, make_production_mesh
from .roofline import parse_collectives, roofline

HBM_PER_CHIP = 16e9  # v5e


def _sds_with_sharding(abstract_tree, sharding_tree):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_tree, sharding_tree)


def _abstract_params(cfg, mesh):
    if cfg.family == "encdec":
        spec = ED.encdec_spec(cfg, cfg.n_enc, cfg.n_dec)
    else:
        spec = LM.lm_spec(cfg)
    abstract = L.abstract_params(spec)
    axes = L.axes_tree(spec)
    shardings = SH.param_sharding_rules(mesh, abstract, axes)
    return _sds_with_sharding(abstract, shardings), spec


def active_param_count(cfg) -> int:
    """Total params, with routed experts scaled by topk/n_routed."""
    if cfg.family == "encdec":
        spec = ED.encdec_spec(cfg, cfg.n_enc, cfg.n_dec)
    else:
        spec = LM.lm_spec(cfg)
    total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(
            spec, is_leaf=lambda x: isinstance(x, L.PSpec))[0]:
        n = int(np.prod(s.shape))
        keys = [str(getattr(p, "key", "")) for p in path]
        if cfg.moe and any(k.startswith("we_") for k in keys):
            n = n * cfg.moe.topk // cfg.moe.n_routed
        total += n
    return total


def _batch_sharded(cfg, mesh, shape):
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if v.ndim == 0:
            spec = SH.spec(mesh)
        else:
            logical = ["batch"] + [None] * (v.ndim - 1)
            out_spec = SH.logical_to_spec(mesh, logical, v.shape)
            spec = jax.sharding.NamedSharding(mesh, out_spec)
        out[k] = jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=spec)
    return out


def _cache_sharded(cfg, mesh, shape):
    B, S = shape.global_batch, shape.seq_len
    dp = SH.axis_size(mesh, SH.batch_axes(mesh)) if SH.batch_axes(mesh) else 1
    seq_ax = "kv_seq_model" if B % max(dp, 1) == 0 and B >= dp \
        else "kv_seq_pdm"
    if cfg.family == "encdec":
        abstract = ED.abstract_encdec_cache(cfg, cfg.n_dec, B, S,
                                            min(S, 4096))
        axes = {k: v.replace("kv_seq_model", seq_ax)
                for k, v in ED.encdec_cache_axes(
                    cfg, cfg.n_dec, B, S, min(S, 4096)).items()}
    else:
        abstract = LM.abstract_cache(cfg, B, S)
        axes = jax.tree.map(
            lambda s: s.replace("kv_seq_model", seq_ax),
            LM.cache_axes(cfg, B, S))
    shardings = SH.param_sharding_rules(mesh, abstract, axes)
    return _sds_with_sharding(abstract, shardings)


def build_lowerable(cfg, mesh, shape, *, microbatch: int = 8):
    """Returns (jitted_fn, example_args) ready to .lower()."""
    params_sds, spec = _abstract_params(cfg, mesh)
    if shape.kind == "train":
        opt_abs = jax.eval_shape(adamw_init, params_sds)
        opt_axes = type(opt_abs)(
            m=L.axes_tree(spec), v=L.axes_tree(spec), count="")
        opt_shard = SH.param_sharding_rules(
            mesh, opt_abs.m, L.axes_tree(spec))
        opt_sds = type(opt_abs)(
            m=_sds_with_sharding(opt_abs.m, opt_shard),
            v=_sds_with_sharding(opt_abs.v, opt_shard),
            count=jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=SH.spec(mesh)))
        batch_sds = _batch_sharded(cfg, mesh, shape)
        # microbatch 8: divides the remat-boundary activation saves (the
        # dominant per-device activation term at 1M tokens/step) while
        # keeping per-microbatch batch divisible by the data axes.
        step_fn = make_train_step(cfg, AdamWConfig(), mesh,
                                  microbatch=microbatch)
        fn = jax.jit(step_fn, donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32, sharding=SH.spec(mesh)))
        return fn, args

    if shape.kind == "prefill":
        batch_sds = _batch_sharded(cfg, mesh, shape)
        if cfg.family == "encdec":
            def prefill(params, batch):
                enc = ED.encode(params, batch["frames"], cfg, mesh)
                return ED.decode_train(params, enc, batch["tokens"], cfg,
                                       mesh=mesh, last_only=True)
        elif cfg.family == "vlm":
            def prefill(params, batch):
                return LM.lm_forward(
                    params, batch["tokens"], cfg, mesh=mesh,
                    prefix_embeds=batch["patch_embeds"], return_cache=True,
                    last_only=True)
        else:
            def prefill(params, batch):
                return LM.lm_forward(params, batch["tokens"], cfg,
                                     mesh=mesh, return_cache=True,
                                     last_only=True)
        return jax.jit(prefill), (params_sds, batch_sds)

    # decode
    cache_sds = _cache_sharded(cfg, mesh, shape)
    batch_sds = _batch_sharded(cfg, mesh, shape)
    if cfg.family == "encdec":
        def decode(params, cache, batch):
            return ED.encdec_decode_step(params, cache, batch["tokens"],
                                         batch["pos"], cfg)
    else:
        def decode(params, cache, batch):
            return LM.lm_decode_step(params, cache, batch["tokens"],
                                     batch["pos"], cfg, mesh=mesh)
    fn = jax.jit(decode, donate_argnums=(1,))
    return fn, (params_sds, cache_sds, batch_sds)


def _compile_cell(cfg, mesh, shape, microbatch):
    fn, args = build_lowerable(cfg, mesh, shape, microbatch=microbatch)
    with mesh:
        compiled = fn.lower(*args).compile()
    return compiled


def _costs_of(compiled, n_dev):
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text(), n_dev)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": colls["total_wire_bytes"],
            "colls": colls}


def _depth_cfg(cfg, r):
    import dataclasses
    kw = {"repeats": r, "scan_unroll": True}
    if cfg.family == "encdec":
        kw.update(n_enc=r, n_dec=r)
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             outdir: Optional[str] = None, *, microbatch: int = 0,
             overrides: Optional[Dict] = None,
             cost_depths=(2, 4)) -> Dict:
    """Two-pass dry-run cell:

    1. MEMORY/COMPILE pass — the FULL config exactly as production would
       run it (rolled layer scans, microbatched train step): proves the
       (arch x shape x mesh) cell lowers, compiles, and fits HBM.
    2. COST pass — XLA's cost_analysis counts rolled scan bodies once, so
       the exact FLOP/byte/collective totals come from two UNROLLED
       compiles at reduced depths r1 < r2; per-layer costs are linear in
       depth (identical per-layer shapes), so totals extrapolate exactly:
       total = A + (B - A)/(r2 - r1) * (full_depth - r1).
    """
    import dataclasses
    cfg = configs.get(arch)
    cfg = dataclasses.replace(cfg, **(overrides or {}))
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        cell.update(status="skipped", reason=why)
        return cell
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    # single-pod: 16 microbatches (per-micro batch 16 = data axis); the
    # multi-pod data degree is 32, so 8 is the divisibility ceiling there.
    if microbatch == 0:
        microbatch = 8 if multi_pod else 16
    mb = microbatch if shape.kind == "train" else 1

    # ---- pass 1: full-depth memory/compile ------------------------------
    t0 = time.time()
    compiled = _compile_cell(cfg, mesh, shape, mb)
    t1 = time.time()
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_estimate_bytes": (ma.argument_size_in_bytes
                                + ma.output_size_in_bytes
                                + ma.temp_size_in_bytes
                                - ma.alias_size_in_bytes),
    }
    fits = mem["peak_estimate_bytes"] < HBM_PER_CHIP

    # ---- pass 2: unrolled cost extrapolation ----------------------------
    r1, r2 = cost_depths
    full_r = cfg.n_enc if cfg.family == "encdec" else cfg.repeats
    r1, r2 = min(r1, full_r), min(r2, full_r)
    ca = _costs_of(_compile_cell(_depth_cfg(cfg, r1), mesh, shape, 1), n_dev)
    if r2 > r1:
        cb = _costs_of(_compile_cell(_depth_cfg(cfg, r2), mesh, shape, 1),
                       n_dev)
    else:
        cb = ca
    t2 = time.time()

    def extrap(key):
        a, b = ca[key], cb[key]
        d = (b - a) / max(r2 - r1, 1)
        return a + d * (full_r - r1)

    cost = {"flops": extrap("flops"), "bytes accessed": extrap("bytes")}
    colls = {"total_wire_bytes": extrap("wire"),
             "at_depth_" + str(r1): ca["colls"],
             "at_depth_" + str(r2): cb["colls"]}
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    from .roofline import analytic_hbm_bytes
    dp = SH.axis_size(mesh, SH.batch_axes(mesh))
    tp = dict(mesh.shape).get("model", 1)
    n_layers = (cfg.n_enc + cfg.n_dec if cfg.family == "encdec"
                else cfg.n_layers)
    cache_dev = 0.0
    if shape.kind == "decode":
        cache_abs = (ED.abstract_encdec_cache(
            cfg, cfg.n_dec, shape.global_batch, shape.seq_len,
            min(shape.seq_len, 4096)) if cfg.family == "encdec"
            else LM.abstract_cache(cfg, shape.global_batch, shape.seq_len))
        cache_dev = sum(
            float(np.prod(a.shape)) * a.dtype.itemsize
            for a in jax.tree.leaves(cache_abs)) / n_dev
    ana = analytic_hbm_bytes(
        n_params=(L.param_count(ED.encdec_spec(cfg, cfg.n_enc, cfg.n_dec))
                  if cfg.family == "encdec" else LM.num_params(cfg)),
        n_params_active=active_param_count(cfg), tokens=tokens,
        d_model=cfg.d_model, n_layers=n_layers, vocab=cfg.vocab_padded,
        n_dev=n_dev, dp=dp, tp=tp, kind=shape.kind, microbatch=mb,
        cache_bytes_per_dev=cache_dev)
    rf = roofline(cost, colls, n_devices=n_dev, tokens=tokens,
                  n_params_active=active_param_count(cfg),
                  kind=shape.kind, analytic_bytes=ana)
    cell.update(status="ok", compile_s=round(t1 - t0, 2),
                cost_compile_s=round(t2 - t1, 2),
                memory=mem, fits_hbm=bool(fits),
                collectives=colls, roofline=rf)
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        path = os.path.join(outdir, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(cell, f, indent=1)
    return cell


# ---------------------------------------------------------------------------
# graph-engine dry-run (the paper's own technique at pod scale)
# ---------------------------------------------------------------------------

def run_graph_cell(exchange: str, multi_pod: bool, algo: str = "wcc",
                   outdir: Optional[str] = None,
                   scale: int = 26, edge_factor: int = 16) -> Dict:
    from ..core import algorithms as ALG
    from ..core.engine_shardmap import ShardEngine, ShardMeta, abstract_shard_data
    mesh = make_graph_mesh(multi_pod=multi_pod)
    P = mesh.size
    V = 1 << scale
    E = edge_factor * V
    v_max = -(-V // P // 256) * 256
    e_pair = -(-E // (P * P) // 32) * 32 * 4  # 4x imbalance headroom
    meta = ShardMeta(P=P, v_max=v_max, e_pair_max=e_pair,
                     n_tiles=-(-(E // P) // 512), n_windows=-(-(v_max + 1)
                                                              // 256),
                     tile_e=512, tile_r=256, num_vertices=V,
                     frontier_capacities=(v_max // 16, v_max // 4, v_max))
    kernel = ALG.ALGORITHMS[algo]()
    eng = ShardEngine(kernel, meta, mesh=mesh, exchange=exchange,
                      backend="ref")
    data_sds = abstract_shard_data(meta, mesh, exchange)
    mesh_name = "multipod_512" if multi_pod else "pod_256"
    cell = {"arch": f"gravfm-{algo}-{exchange}", "shape": f"rmat{scale}",
            "mesh": mesh_name}
    from jax.sharding import PartitionSpec as PS

    state_sds = jax.eval_shape(
        lambda g, o, v: kernel.init_state(g, o, v, num_vertices=V),
        jax.ShapeDtypeStruct((P, v_max), jnp.int32),
        jax.ShapeDtypeStruct((P, v_max), jnp.int32),
        jax.ShapeDtypeStruct((P, v_max), bool))

    def superstep(d, payload, active, state):
        # shard blocks keep a size-1 leading axis
        sq = lambda t: jax.tree.map(lambda a: a[0], t)
        st, p2, a2, n, w = eng._shard_step(
            sq(d), payload[0], active[0], sq(state), jnp.int32(1))
        n = jax.lax.psum(n, "graph")
        w = jax.lax.psum(w, "graph")
        ex = lambda t: jax.tree.map(lambda a: a[None], t)
        return ex(st), p2[None], a2[None], n, w

    shard_fn = jax.shard_map(
        superstep, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: PS("graph"), data_sds),
                  PS("graph"), PS("graph"),
                  jax.tree.map(lambda _: PS("graph"), state_sds)),
        out_specs=(PS("graph"), PS("graph"), PS("graph"), PS(), PS()),
        check_vma=False)

    payload_sds = jax.ShapeDtypeStruct((P, v_max), kernel.msg_dtype)
    active_sds = jax.ShapeDtypeStruct((P, v_max), jnp.bool_)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(shard_fn).lower(
            data_sds, payload_sds, active_sds, state_sds)
        compiled = lowered.compile()
    t1 = time.time()
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text(), P)
    ma = compiled.memory_analysis()
    # paper-units: traversed edges per superstep = E; TEPS bound per term
    rf = roofline(cost, colls, n_devices=P, tokens=E,
                  n_params_active=0, kind="prefill")
    cell.update(status="ok", compile_s=round(t1 - t0, 2),
                edges_per_superstep=E,
                teps_bound=E / max(rf["roofline_step_s"], 1e-30),
                memory={"argument_bytes": ma.argument_size_in_bytes,
                        "temp_bytes": ma.temp_size_in_bytes},
                collectives=colls, roofline=rf)
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(
                outdir, f"graph__{algo}__{exchange}__{mesh_name}.json"),
                "w") as f:
            json.dump(cell, f, indent=1)
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--graph", action="store_true")
    ap.add_argument("--exchange", default="allgather")
    ap.add_argument("--algo", default="wcc")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = []
    failures = 0

    if args.graph:
        for mp in meshes:
            cell = run_graph_cell(args.exchange, mp, args.algo, args.out)
            results.append(cell)
            print(json.dumps(cell, indent=1)[:400])
    else:
        archs = configs.ARCH_IDS if (args.all or not args.arch) \
            else [args.arch]
        shapes = list(SHAPES) if (args.all or not args.shape) \
            else [args.shape]
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    try:
                        cell = run_cell(arch, shape, mp, args.out)
                    except Exception as e:
                        traceback.print_exc()
                        cell = {"arch": arch, "shape": shape,
                                "mesh": "multi" if mp else "single",
                                "status": "FAILED", "error": str(e)[:500]}
                        failures += 1
                    results.append(cell)
                    s = cell.get("status")
                    extra = ""
                    if s == "ok":
                        rf = cell["roofline"]
                        extra = (f" bound={rf['bound_by']}"
                                 f" step={rf['roofline_step_s']:.4f}s"
                                 f" fits={cell['fits_hbm']}"
                                 f" compile={cell['compile_s']}s")
                    print(f"[{s:7s}] {cell['arch']:22s} {cell['shape']:12s}"
                          f" {cell['mesh']:18s}{extra}", flush=True)
    summary = os.path.join(args.out, "summary.json")
    os.makedirs(args.out, exist_ok=True)
    with open(summary, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {summary}; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
