"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
      --reduced --steps 100 --ckpt-dir /tmp/ckpt

On real hardware the same entrypoint builds the production mesh and
shards params/optimizer/batch per sharding.py; on this CPU box it runs
the reduced config on the local device. The loop resumes from the latest
complete checkpoint automatically — relaunch after any failure (or on a
different mesh: checkpoints reshard on restore).
"""
from __future__ import annotations

import argparse

import jax

from .. import configs
from ..data.pipeline import DataConfig
from ..train.loop import TrainConfig, Trainer
from ..train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale config (default on this box)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="build the (16,16) mesh (requires 256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch, reduced=args.reduced
                      or len(jax.devices()) == 1)
    mesh = None
    if args.production_mesh:
        from .mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    dc = DataConfig(vocab=cfg.vocab, global_batch=args.global_batch,
                    seq_len=args.seq_len)
    oc = AdamWConfig(lr_peak=args.lr, warmup_steps=max(1, args.steps // 20),
                     total_steps=args.steps)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, log_every=10,
                     microbatch=args.microbatch)
    out = Trainer(cfg, dc, oc, tc, mesh=mesh).run()
    for s, l in out["losses"]:
        print(f"step {s:5d} loss {l:.4f}")
    print(f"done: step {out['final_step']} wall {out['seconds']:.1f}s")


if __name__ == "__main__":
    main()
